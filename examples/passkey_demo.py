"""The paper's headline contrast, live: eviction forgets, retrieval recalls.

    PYTHONPATH=src python examples/passkey_demo.py

Trains a small LM on the passkey task (cached after first run), hides a
5-digit key deep in filler context, then decodes the answer under three
cache policies at the same tiny budget:

    SLM  (eviction)  — sink+recent only: the passkey tokens are long gone
    Quest (pages)    — page min/max retrieval
    FIER (this repo) — token-level 1-bit retrieval
"""
import sys

sys.path.insert(0, "benchmarks")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.passkey import N_DIGITS, make_passkey_batch

from common import policy_bundle, train_tiny_lm  # noqa: E402


def main():
    cfg, params = train_tiny_lm("passkey", steps=600)
    params = jax.tree.map(jnp.asarray, params)
    SEQ, budget = 256, 32

    batch, answers = make_passkey_batch(cfg, 4, SEQ, seed=7, step=0, depth=0.3)
    prompt = batch["tokens"][:, : SEQ - N_DIGITS]
    B = prompt.shape[0]
    print(f"context={SEQ} tokens, budget={budget} ({budget/SEQ:.0%}), "
          f"passkey at 30% depth\n")
    for kind in ("full", "slm", "quest", "fier"):
        bundle = policy_bundle(cfg, kind, budget)
        pre = {"tokens": prompt, "lengths": jnp.full((B,), prompt.shape[1], jnp.int32)}
        logits, cache = jax.jit(
            lambda p, b: bundle.prefill(p, b, capacity=SEQ + 8)
        )(params, pre)
        decode = jax.jit(bundle.decode_step)
        digs = []
        for _ in range(N_DIGITS):
            tok = jnp.argmax(logits[:, :10], axis=-1).astype(jnp.int32)
            digs.append(tok)
            logits, cache = decode(params, tok, cache)
        got = np.stack([np.asarray(d) for d in digs], 1)
        acc = (got == np.asarray(answers)).all(1).mean()
        print(f"{kind:6s}: answered {got[0].tolist()} "
              f"(true {np.asarray(answers)[0].tolist()}) — batch acc {acc:.0%}")


if __name__ == "__main__":
    main()
