"""Serving demo: continuous batching with FIER-retrieval decode.

    PYTHONPATH=src python examples/serve_longcontext.py

Seven requests share four engine slots; the scheduler admits/retires
continuously while every decode step runs FIER top-k attention over the
1-bit side-car.  Prints per-request outputs + engine utilisation.
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.data.pipeline import lm_tokens
from repro.models import build_model
from repro.serving import ContinuousScheduler, Engine, Request


def main():
    cfg = reduced_config("llava-next-mistral-7b")  # mistral-like backbone
    # pipeline="one_pass": the serving default — one-pass retrieval
    # (scores never touch HBM) + fused select-and-attend, no materialised
    # K'/V' gather (DESIGN.md §One-pass retrieval).  Other pipelines:
    # "two_pass" (kernel ablation), "reference" (jnp oracle); add
    # layout="paged" for the block-pool cache.
    pol = PolicyConfig(kind="fier", budget=24, group=8, skip_layers=1,
                       pipeline="one_pass")
    bundle = build_model(cfg, pol)
    params = bundle.init(jax.random.PRNGKey(0))

    engine = Engine(bundle, n_slots=4, capacity=128)
    sched = ContinuousScheduler(engine, params, pad_prompt_to=32)

    toks = np.asarray(lm_tokens(1, 0, 7, 32, cfg.vocab))
    reqs = [
        Request(rid=i, tokens=toks[i, : 20 + 2 * i].tolist(), max_new=8 + i)
        for i in range(7)
    ]
    t0 = time.time()
    outs = sched.run(reqs)
    wall = time.time() - t0
    for rid, out in sorted(outs.items()):
        print(f"req {rid}: {len(out)} tokens → {out}")
    total = sum(len(v) for v in outs.values())
    print(f"\n{total} tokens in {wall:.1f}s ({total/wall:.1f} tok/s), "
          f"decode steps={sched.steps}, mean slot occupancy="
          f"{sched.mean_occupancy:.2f}/4")


if __name__ == "__main__":
    main()
