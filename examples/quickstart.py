"""Quickstart: build an LM with FIER-retrieval decode and generate text.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end: config → model bundle (with a cache
policy) → prefill → decode loop, and compares the FIER output against
Full-KV on the same prompt.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.policy import PolicyConfig
from repro.data.pipeline import lm_tokens
from repro.models import build_model


def generate(bundle, params, prompt, n_new=12):
    B, S = prompt.shape
    pre = {"tokens": prompt, "lengths": jnp.full((B,), S, jnp.int32)}
    # cache capacity must be a multiple of the FIER group (the 1-bit
    # side-car packs 8 tokens/byte, one (scale, zero) cell per group)
    cap = -(-(S + n_new) // 16) * 16
    logits, cache = jax.jit(lambda p, b: bundle.prefill(p, b, capacity=cap))(
        params, pre
    )
    out = []
    decode = jax.jit(bundle.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(n_new):
        out.append(np.asarray(tok))
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return np.stack(out, 1)


def main():
    cfg = reduced_config("olmo-1b")
    print(f"model: {cfg.name} (reduced) — {cfg.n_layers}L d={cfg.d_model}")

    # FIER: 1-bit quantized key retrieval, token budget 16, group size 8.
    # pipeline="reference" is the pure-jnp oracle pipeline (easy to read
    # and step through); serving uses pipeline="one_pass" — the fused
    # Pallas fast path (see examples/serve_longcontext.py and DESIGN.md
    # §Backend registry & DecodePlan)
    fier = PolicyConfig(kind="fier", budget=16, group=8, skip_layers=1,
                        pipeline="reference")
    bundle_fier = build_model(cfg, fier)
    bundle_full = build_model(cfg, PolicyConfig(kind="full"))

    params = bundle_fier.init(jax.random.PRNGKey(0))
    prompt = lm_tokens(0, 0, 2, 48, cfg.vocab)[:, :48]

    out_full = generate(bundle_full, params, prompt)
    out_fier = generate(bundle_fier, params, prompt)
    agree = (out_full == out_fier).mean()

    print("full-KV :", out_full[0].tolist())
    print("fier    :", out_fier[0].tolist())
    print(f"greedy agreement at {16/48:.0%} budget: {agree:.0%}")
    print("(random init — run examples/train_then_serve.py for a trained model)")


if __name__ == "__main__":
    main()
