"""End-to-end training driver demo with fault injection + recovery.

    PYTHONPATH=src python examples/train_tiny_lm.py

Trains a reduced OLMo on the deterministic bigram stream for 60 steps,
crashes itself at steps 25 and 45 (injected), recovers from checkpoints,
and verifies the loss went down.  This is the same driver that runs at
pod scale (repro.launch.train).
"""
import subprocess
import sys

CMD = [
    sys.executable, "-m", "repro.launch.train",
    "--arch", "olmo-1b", "--reduced",
    "--steps", "60", "--batch", "8", "--seq", "64",
    "--ckpt-every", "10", "--fail-at", "25", "45",
    "--ckpt-dir", "/tmp/repro_example_ckpt",
    "--log-every", "10",
]


def main():
    print("running:", " ".join(CMD))
    r = subprocess.run(CMD, env={"PYTHONPATH": "src"}, cwd=".")
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
